"""Prefetch-on-affinity-hint, sim plane (DESIGN.md §12).

The contract under test: a placement-time hint OVERLAPS the store->host
read with the phases ahead of the load — it never changes which bytes move
(tier byte counters are identical to the unhinted run) and never makes any
load slower.  The overlap formula is pinned exactly against the cost model,
and SimHostCache's TTL aging is pinned so prefetch is measured against
churn, not a static cache.
"""
import dataclasses

from repro.core import POLICIES, ClusterSim, generate_trace
from repro.core.costmodel import PhaseCosts, paper_l40
from repro.core.hostcache import SimHostCache
from repro.core.reuse_store import ReuseStore
from repro.core.trace import PAPER_MODELS
from repro.models.tensors import TensorRecord


def recs(model_id, sizes):
    return [TensorRecord(name=f"{model_id}/t{i}", shape=(s,), dtype="uint8",
                         fingerprint=f"{model_id}/t{i}", nbytes=s)
            for i, s in enumerate(sizes)]


HW = paper_l40()
COSTS = PhaseCosts(HW)
SLOW = min(HW.h2d_bw, HW.store_bw)


# ------------------------------------------------------------- cost model
def test_load_time_prefetched_degenerates_to_tiered_at_zero_overlap():
    # with no host bytes and no window there is nothing to hide behind
    assert COSTS.load_time_prefetched(0, 2e9, 0.0) == \
        COSTS.load_time_tiered(0, 2e9)
    # with host bytes the hinted read overlaps their h2d — something the
    # serial tiered pipeline never does — so the price can only drop
    assert COSTS.load_time_prefetched(3e9, 2e9, 0.0) <= \
        COSTS.load_time_tiered(3e9, 2e9)


def test_load_time_prefetched_monotone_and_floored_at_all_host():
    host, store = 1e9, 8e9
    prev = COSTS.load_time_tiered(host, store)
    floor = COSTS.load_time_tiered(host + store, 0)
    for w in (0.1, 0.5, 1.0, 2.0, 10.0, 1e4):
        t = COSTS.load_time_prefetched(host, store, w)
        assert t <= prev + 1e-12  # longer window never hurts
        assert t >= floor - 1e-9  # and never beats an all-host load
        prev = t
    assert COSTS.load_time_prefetched(host, store, 1e4) == floor


def test_prefetch_hidden_bytes_clipped_by_window_and_store():
    # window too small to hide everything: hidden = window * store_bw
    w = 0.5
    hidden = COSTS.prefetch_hidden_bytes(0, 8e9, w)
    assert hidden == w * HW.store_bw < 8e9
    # huge window: hidden clips at the store bytes themselves
    assert COSTS.prefetch_hidden_bytes(0, 8e9, 1e4) == 8e9
    # host bytes extend the window by their own h2d time
    assert COSTS.prefetch_hidden_bytes(2e9, 8e9, w) == \
        (w + 2e9 / HW.h2d_bw) * HW.store_bw


# ---------------------------------------------------------- SimHostCache
def test_take_prefetch_returns_elapsed_and_covered_once():
    hc = SimHostCache(10**9)
    r = recs("m", [100, 200])
    hc.prefetch("m", r, now=5.0)
    assert hc.take_prefetch("other", 9.0, r) is None
    assert hc.take_prefetch("m", 9.0, r) == (4.0, 300)  # both still absent
    assert hc.take_prefetch("m", 9.0, r) is None  # consumed


def test_take_prefetch_covers_only_hint_time_absences():
    """Tensors that spill AFTER the hint were never part of its background
    read — the covered bytes (and therefore the hidden cap) exclude them."""
    hc = SimHostCache(10**9)
    r = recs("m", [100, 200])
    hc.plan_fetch(r[:1], now=0.0)  # t0 host-resident at hint time
    hc.prefetch("m", r, now=1.0)  # snapshot: only t1 (200) absent
    hc._evict(r[0].fingerprint)  # t0 spills after the hint fired
    elapsed, covered = hc.take_prefetch("m", 3.0, r)
    assert elapsed == 2.0
    assert covered == 200  # t0's 100 bytes get no overlap credit


def test_hint_ttl_expires_unconsumed_hints():
    """A hint whose placement never followed through (dropped schedule,
    warm start) must not grant a much-later load its overlap window."""
    hc = SimHostCache(10**9, hint_ttl_s=10.0)
    r = recs("m", [100])
    hc.prefetch("m", r, now=0.0)
    assert hc.take_prefetch("m", 50.0, r) is None  # stale: no credit
    hc.prefetch("m", r, now=60.0)  # a fresh hint works normally
    assert hc.take_prefetch("m", 65.0, r) == (5.0, 100)


def test_ttl_ages_idle_tensors_into_store_traffic():
    hc = SimHostCache(10**9, keep_alive_s=5.0)
    r = recs("m", [100, 200])
    assert hc.plan_fetch(r, now=0.0) == (0, 300)  # cold: all store
    assert hc.plan_fetch(r, now=4.0) == (300, 0)  # inside TTL: host hits
    host, store = hc.plan_fetch(r, now=20.0)  # idle > TTL: aged out
    assert (host, store) == (0, 300)
    assert hc.expirations == 2
    assert hc.nbytes() == 300  # re-admitted by the fetch


def test_ttl_none_never_expires():
    hc = SimHostCache(10**9)
    r = recs("m", [100])
    hc.plan_fetch(r, now=0.0)
    assert hc.plan_fetch(r, now=1e9) == (100, 0)
    assert hc.expirations == 0


# --------------------------------------------- ReuseStore overlap pricing
def _loaded_store(cap_cache=150):
    store = ReuseStore(10**9, COSTS)
    store.host_cache = SimHostCache(cap_cache)
    return store


def test_overlap_accounting_exact_vs_unhinted_run():
    """The hinted run moves EXACTLY the same bytes through each tier as the
    unhinted run — only the modeled wall time shrinks, by the overlapped
    window's worth of store read re-priced from the store pipeline to
    h2d_bw."""
    r = recs("m", [100, 100, 100])
    rx = recs("x", [150])

    def run(hinted: bool):
        store = _loaded_store(cap_cache=350)
        store.load_model("m", r, now=0.0)  # cold: cache holds all of m
        store.release("m")
        # x's admission over the 350-byte cap LRU-spills m's oldest tensor,
        # so m's reload faces a genuine host/store split
        store.load_model("x", rx, now=1.0)
        store.drop_model("m")  # force a full device-pool transfer next load
        if hinted:
            store.hint_prefetch("m", r, now=10.0)
        return store.load_model("m", r, now=12.0, overlap_s=0.5)

    plain, hinted = run(False), run(True)
    # identical tier byte split: overlap, not avoidance
    assert (hinted.bytes_from_host, hinted.bytes_from_store) == \
        (plain.bytes_from_host, plain.bytes_from_store)
    assert plain.bytes_from_store > 0  # the cap actually spilled something
    assert not plain.prefetched and hinted.prefetched
    # exact overlap formula: window = (12 - 10) elapsed + 0.5 init
    window = 2.0 + 0.5
    assert hinted.bytes_store_hidden == int(COSTS.prefetch_hidden_bytes(
        hinted.bytes_from_host, hinted.bytes_from_store, window))
    assert hinted.load_seconds == COSTS.load_time_prefetched(
        hinted.bytes_from_host, hinted.bytes_from_store, window)
    assert plain.load_seconds == COSTS.load_time_tiered(
        plain.bytes_from_host, plain.bytes_from_store)
    # wall time shrinks by exactly the hidden bytes' pipeline-vs-h2d delta
    hidden = hinted.bytes_store_hidden
    expect_gain = hidden / SLOW - hidden / HW.h2d_bw
    assert abs((plain.load_seconds - hinted.load_seconds) - expect_gain) \
        < 1e-9


def test_hint_is_consumed_by_one_load():
    r = recs("m", [100, 100, 100])
    rx = recs("x", [150])
    store = _loaded_store(cap_cache=350)
    store.load_model("m", r, now=0.0)
    store.release("m")
    store.load_model("x", rx, now=1.0)  # spills m's LRU tensor
    store.drop_model("m")
    store.hint_prefetch("m", r, now=2.0)
    first = store.load_model("m", r, now=3.0)
    assert first.prefetched and first.bytes_store_hidden > 0
    store.release("m")
    store.drop_model("m")
    second = store.load_model("m", r, now=4.0)  # no fresh hint
    assert not second.prefetched and second.bytes_store_hidden == 0


def test_hint_covering_no_bytes_does_not_count_as_prefetched():
    """A hint issued while everything was host-resident covered nothing —
    the load must not be flagged prefetched even if bytes move later."""
    r = recs("m", [100, 100, 100])
    store = _loaded_store(cap_cache=10**9)
    store.load_model("m", r, now=0.0)
    store.release("m")
    store.drop_model("m")  # device-pool drop only: host tier still full
    store.hint_prefetch("m", r, now=1.0)  # snapshot: nothing absent
    rep = store.load_model("m", r, now=2.0)
    assert rep.bytes_transferred == 300 and rep.bytes_from_store == 0
    assert not rep.prefetched and rep.bytes_store_hidden == 0


def test_hint_without_host_cache_is_noop():
    store = ReuseStore(10**9, COSTS)
    r = recs("m", [100])
    store.hint_prefetch("m", r, now=0.0)  # must not raise
    rep = store.load_model("m", r, now=1.0)
    assert not rep.prefetched


# ------------------------------------------------------------- cluster sim
def _run_policy(policy_name, **overrides):
    trace = generate_trace(n_requests=160, locality="L3",
                           mean_interarrival=8.0, seed=77,
                           max_output_tokens=128)
    pol = dataclasses.replace(POLICIES[policy_name], **overrides)
    sim = ClusterSim(PAPER_MODELS, pol, n_workers=2, seed=77)
    return sim.run(trace), sim


def test_cluster_prefetch_invariants():
    res, _ = _run_policy("tangram-prefetch")
    assert len(res) == 160
    prefetched = [r for r in res if r.prefetched]
    assert prefetched, "no load ever carried a hint"
    for r in res:
        # tier identity holds with hidden bytes a subset of store bytes
        assert r.bytes_from_host + r.bytes_from_store == r.bytes_transferred
        assert 0 <= r.bytes_store_hidden <= r.bytes_from_store
        if r.prefetched:
            # overlap pricing is never worse than the unhinted tier price
            assert r.load_s <= COSTS.load_time_tiered(
                r.bytes_from_host, r.bytes_from_store) + 1e-9
    assert any(r.bytes_store_hidden > 0 for r in prefetched)


def test_cluster_prefetch_never_slower_than_tier_on_same_trace():
    """Same workload, same seeds: hints only ever shrink modeled load time,
    so the fleet-wide load total cannot grow."""
    tier, _ = _run_policy("tangram-tier")
    pf, _ = _run_policy("tangram-prefetch")
    assert sum(r.load_s for r in pf) <= sum(r.load_s for r in tier) + 1e-6


def test_cluster_host_keep_alive_increases_store_traffic():
    """Aging the host tier (TTL) forces re-promotions: store traffic with a
    short keep-alive must exceed the static cache's, and expirations must
    actually have happened."""
    static, _ = _run_policy("tangram-tier")
    aged, sim = _run_policy("tangram-tier", host_keep_alive=30.0)
    assert sum(w.host_cache.expirations for w in sim.workers) > 0
    assert sum(r.bytes_from_store for r in aged) > \
        sum(r.bytes_from_store for r in static)


# --------------------------------------- real-plane deadline scheduling
def _stub_engine(spilled: dict[str, bytes]):
    """A minimal engine facade for the Prefetcher: a real tiered host store
    (numpy-backed) plus the store lock — no jax, no model registry."""
    import threading
    import types

    import numpy as np

    from repro.models.tensors import HostTensorStore

    eng = types.SimpleNamespace()
    eng.host_store = HostTensorStore(10**9)
    eng.persistent_store = eng.host_store.spill
    eng._store_lock = threading.RLock()
    for fp, size in spilled.items():
        eng.persistent_store.put(fp, np.zeros(size, np.uint8))
    return eng


def test_prefetcher_interleaves_racing_hints_by_deadline():
    """Bytes-until-deadline priority (the ROADMAP item FIFO left open):
    with two hinted models racing one store, promotions must follow the
    globally smallest h2d-prefix deadline — each load's earliest-needed
    tensors first — not whole-model FIFO order."""
    from repro.serving.engine import Prefetcher

    a = {f"a{i}": 10 for i in range(3)}
    b = {f"b{i}": 10 for i in range(3)}
    eng = _stub_engine({**a, **b})
    pf = Prefetcher(eng)
    pf.pause()  # freeze scheduling so both hints are pending together
    # deadlines: a's tensors sit at h2d prefixes 0/100/400, b's at 50/150/200
    ja = pf.submit("a", ["a0", "a1", "a2"], False, deadlines=[0.0, 100.0, 400.0])
    jb = pf.submit("b", ["b0", "b1", "b2"], False, deadlines=[50.0, 150.0, 200.0])
    pf.resume()
    for job in (ja, jb):
        job.done.wait(5.0)
        assert job.done.is_set()
    # merged global deadline order, NOT [a0 a1 a2 b0 b1 b2] (FIFO)
    assert pf.promote_log == [("a", "a0"), ("b", "b0"), ("a", "a1"),
                              ("b", "b1"), ("b", "b2"), ("a", "a2")]
    assert pf.bytes_promoted == 60
    pf.close()


def test_prefetcher_urgent_join_drains_job_first():
    """A load joining a STARTED job blocks on job.done — its remaining
    tensors must jump every other job's deadlines."""
    import time as _t

    from repro.serving.engine import Prefetcher

    sizes = {f"a{i}": 10 for i in range(3)} | {f"b{i}": 10 for i in range(3)}
    eng = _stub_engine(sizes)
    pf = Prefetcher(eng)
    pf.pause()
    # interleaved deadlines: unhinted EDF order would be a0 b0 a1 b1 a2 b2
    pf.submit("a", ["a0", "a1", "a2"], False, deadlines=[0.0, 2.0, 4.0])
    job_b = pf.submit("b", ["b0", "b1", "b2"], False, deadlines=[1.0, 3.0, 5.0])
    job_b.started = True  # as if the worker already promoted from b
    pf.resume()
    taken = pf.take("b")  # a load joins b mid-flight -> urgent
    assert taken is job_b and job_b.urgent
    job_b.done.wait(5.0)
    assert job_b.done.is_set()
    deadline = _t.monotonic() + 5.0
    while len(pf.promote_log) < 6 and _t.monotonic() < deadline:
        _t.sleep(0.01)
    b_positions = [i for i, (m, _) in enumerate(pf.promote_log) if m == "b"]
    # every b promotion lands before a's tail: urgent beats deadline order
    assert b_positions and max(b_positions) <= 3, pf.promote_log
    pf.close()


def test_prefetcher_unstarted_take_withdraws_job():
    """Head-of-line bypass survives the EDF rewrite: taking a job the
    worker never started withdraws it (nothing promoted, no waiting)."""
    from repro.serving.engine import Prefetcher

    eng = _stub_engine({"a0": 10})
    pf = Prefetcher(eng)
    pf.pause()
    job = pf.submit("a", ["a0"], False, deadlines=[0.0])
    taken = pf.take("a")
    assert taken is job and job.cancelled and job.done.is_set()
    assert job.tensors_promoted == 0
    pf.resume()
    pf.close()


def test_prefetcher_paused_still_serves_urgent_joins():
    """pause() freezes deadline scheduling but must never deadlock a load
    blocked on a STARTED job — urgent jobs drain through the pause."""
    from repro.serving.engine import Prefetcher

    eng = _stub_engine({"a0": 10, "a1": 10})
    pf = Prefetcher(eng)
    pf.pause()
    job = pf.submit("a", ["a0", "a1"], False, deadlines=[0.0, 1.0])
    job.started = True  # as if the worker was mid-job when paused
    taken = pf.take("a")  # a load joins: urgent, must finish while paused
    assert taken is job and job.urgent
    assert job.done.wait(5.0), "paused prefetcher deadlocked an urgent join"
    assert job.tensors_promoted == 2
    pf.resume()
    pf.close()
