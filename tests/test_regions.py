"""Region-list invariants: coverage, coalescing, best-fit, compaction."""
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.regions import NaiveRegionList, Region, RegionList, RState


def test_basic_alloc_free():
    rl = RegionList(100)
    a = rl.alloc_best_fit(30, RState.TENSOR, "a")
    b = rl.alloc_best_fit(50, RState.TENSOR, "b")
    assert a.offset == 0 and b.offset == 30
    assert rl.free_bytes() == 20
    rl.check()
    rl.free(a.offset)
    assert rl.free_bytes() == 50
    rl.check()
    # best-fit picks the 20-byte tail, not the 30-byte hole
    c = rl.alloc_best_fit(20, RState.TENSOR, "c")
    assert c.offset == 80
    rl.check()


def test_alloc_failure_returns_none():
    rl = RegionList(10)
    assert rl.alloc_best_fit(11, RState.TENSOR, "x") is None
    assert rl.alloc_best_fit(10, RState.TENSOR, "x") is not None
    assert rl.alloc_best_fit(1, RState.TENSOR, "y") is None


def test_free_coalesces_both_sides():
    rl = RegionList(30)
    a = rl.alloc_best_fit(10, RState.TENSOR, "a")
    b = rl.alloc_best_fit(10, RState.TENSOR, "b")
    c = rl.alloc_best_fit(10, RState.TENSOR, "c")
    rl.free(a.offset)
    rl.free(c.offset)
    rl.free(b.offset)
    assert len(rl.regions) == 1 and rl.regions[0].state == RState.FREE
    rl.check()


def test_compact_span_moves_left():
    rl = RegionList(100)
    a = rl.alloc_best_fit(20, RState.TENSOR, "a")  # [0,20)
    b = rl.alloc_best_fit(20, RState.TENSOR, "b")  # [20,40)
    rl.alloc_best_fit(20, RState.TENSOR, "c")  # [40,60)
    rl.free(a.offset)
    # [F20][b][c][F40] -> compact all
    moved, rel = rl.compact_span(0, len(rl.regions) - 1)
    assert moved == 40 and rel == {"b": 0, "c": 20}
    assert rl.largest_free() == 60
    rl.check()


def test_fragmentation_metric():
    rl = RegionList(100)
    xs = [rl.alloc_best_fit(10, RState.TENSOR, f"t{i}") for i in range(10)]
    for x in xs[::2]:
        rl.free(x.offset)
    assert rl.free_bytes() == 50
    assert rl.largest_free() == 10
    assert rl.fragmentation() == pytest.approx(1 - 10 / 50)


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 40)), min_size=1, max_size=60),
       st.randoms(use_true_random=False))
def test_random_alloc_free_invariants(ops, rng):
    """Any alloc/free sequence keeps the list sorted, covering, coalesced."""
    rl = RegionList(256)
    live = []
    for i, (is_alloc, size) in enumerate(ops):
        if is_alloc or not live:
            r = rl.alloc_best_fit(size, RState.TENSOR, f"t{i}")
            if r is not None:
                live.append(r.offset)
        else:
            off = live.pop(rng.randrange(len(live)))
            rl.free(off)
        rl.check()
    used = sum(r.size for r in rl.regions if r.state != RState.FREE)
    assert used + rl.free_bytes() == 256


@settings(max_examples=100, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 40)),
                min_size=1, max_size=60),
       st.randoms(use_true_random=False))
def test_indexed_matches_naive_scans(ops, rng):
    """The indexed RegionList (offset index, size buckets, running counters)
    must make EXACTLY the same decisions as the original O(n)-scan
    implementation on any alloc/free sequence — same placements, same
    best-fit choices, same query answers."""
    fast, slow = RegionList(256), NaiveRegionList(256)
    live = []
    for i, (is_alloc, size) in enumerate(ops):
        if is_alloc or not live:
            rf = fast.alloc_best_fit(size, RState.TENSOR, f"t{i}")
            rs = slow.alloc_best_fit(size, RState.TENSOR, f"t{i}")
            assert (rf is None) == (rs is None)
            if rf is not None:
                assert rf.offset == rs.offset
                live.append(rf.offset)
        else:
            off = live.pop(rng.randrange(len(live)))
            fast.free(off)
            slow.free(off)
        assert fast.free_bytes() == slow.free_bytes()
        assert fast.largest_free() == slow.largest_free()
        assert [(r.offset, r.size, r.state) for r in fast.regions] == \
               [(r.offset, r.size, r.state) for r in slow.regions]
        fast.check()
    for off in live:
        owner = fast._by_offset[off].owner
        f = fast.find(owner)
        s = slow.find(owner)
        assert f is not None and s is not None and f.offset == s.offset


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(1, 30), min_size=2, max_size=12), st.integers(0, 10**6))
def test_compaction_preserves_bytes(sizes, seed):
    rng = random.Random(seed)
    rl = RegionList(512)
    offs = []
    for i, s in enumerate(sizes):
        r = rl.alloc_best_fit(s, RState.TENSOR, f"t{i}")
        if r is not None:
            offs.append((f"t{i}", r.offset, s))
    # free a random subset to create fragmentation
    for name, off, s in offs:
        if rng.random() < 0.5:
            rl.free(off)
    before_used = rl.used_bytes()
    rl.compact_span(0, len(rl.regions) - 1)
    rl.check()
    assert rl.used_bytes() == before_used
    # after full compaction, free space is contiguous
    assert rl.fragmentation() == 0.0
