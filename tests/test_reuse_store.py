"""Reuse Store: hit/miss planning, load reports, eviction-cost policy (Eq. 2)."""
import pytest

from repro.core.allocator import AllocationError
from repro.core.costmodel import PhaseCosts, paper_l40
from repro.core.reuse_store import ReuseStore
from repro.models.tensors import TensorRecord


def recs(model, sizes):
    return [TensorRecord(name=f"{model}/t{i}", shape=(s,), dtype="int8",
                         fingerprint=f"{model}/t{i}", nbytes=s)
            for i, s in enumerate(sizes)]


def mkstore(cap=1000, policy="mce+pgp"):
    return ReuseStore(cap, PhaseCosts(paper_l40()), policy=policy)


def test_cold_load_then_full_reuse():
    store = mkstore()
    r = recs("m1", [100, 200, 50])
    rep1 = store.load_model("m1", r)
    assert rep1.bytes_transferred == 350 and rep1.bytes_hit == 0
    store.release("m1")
    rep2 = store.load_model("m1", r)  # everything still resident
    assert rep2.bytes_hit == 350 and rep2.bytes_transferred == 0
    assert rep2.reuse_fraction == 1.0
    assert rep2.load_seconds == 0.0


def test_partial_reuse_after_pressure_eviction():
    store = mkstore(1000)
    m1 = recs("m1", [300, 300])
    m2 = recs("m2", [300, 200])
    store.load_model("m1", m1)
    store.release("m1")
    rep = store.load_model("m2", m2)  # 600 resident, 500 new: evicts some of m1
    assert rep.bytes_transferred == 500
    store.release("m2")
    rep = store.load_model("m1", m1)
    assert 0 < rep.bytes_hit <= 600
    assert rep.bytes_hit + rep.bytes_transferred == 600


def test_active_models_never_evicted():
    store = mkstore(1000)
    store.load_model("busy", recs("busy", [600]))  # stays active
    with pytest.raises(AllocationError):
        store.load_model("m2", recs("m2", [500]))
    assert store.resident_bytes("busy") == 600


def test_eviction_prefers_low_miss_probability():
    store = mkstore(1000)
    store.load_model("rare", recs("rare", [400]))
    store.release("rare")
    store.load_model("hot", recs("hot", [400]))
    store.release("hot")
    store.miss_prob.update({"rare": 0.05, "hot": 0.9})
    store.load_model("new", recs("new", [300]))
    assert store.resident_bytes("hot") == 400  # hot survived
    assert store.resident_bytes("rare") < 400


def test_alpha_latency_sensitivity():
    store = mkstore(1000)
    store.load_model("a", recs("a", [400]))
    store.release("a")
    store.load_model("b", recs("b", [400]))
    store.release("b")
    store.miss_prob.update({"a": 0.5, "b": 0.5})
    store.alpha.update({"a": 0.01, "b": 1.0})  # a tolerates reloads
    store.load_model("new", recs("new", [300]))
    assert store.resident_bytes("b") == 400
    assert store.resident_bytes("a") < 400


def test_none_policy_is_exclusive():
    store = mkstore(policy="none")
    r = recs("m1", [100])
    store.load_model("m1", r)
    store.release("m1")
    store.drop_model("m1")
    rep = store.load_model("m1", r)
    assert rep.bytes_hit == 0 and rep.bytes_transferred == 100


def test_load_report_time_model():
    store = mkstore(10**10)
    r = recs("m1", [5 * 10**9])
    rep = store.load_model("m1", r)
    assert rep.load_seconds == pytest.approx(1.0)  # 5 GB / 5 GB/s calibrated


def test_urgent_reclaim_contiguous_window():
    store = mkstore(1000)
    # layout: [t0 100][t1 100][t2 100]... with alternating frees -> small holes
    for i in range(10):
        store.load_model(f"m{i}", recs(f"m{i}", [100]))
        store.release(f"m{i}")
    # all resident; no free space. contiguous reclaim must open a 250B hole
    assert store.free_bytes() == 0
    assert store.urgent_reclaim_contiguous(250)
    assert store.pool.largest_free() >= 250


def _sub_block_hole_store():
    """Sequential layout where every inactive tensor is either small (50/100)
    or separated from its free neighbours by ACTIVE tensors, except one pair
    of adjacent 100B inactives (d0, d1) that a sliding window can merge:

      [x0 100 act][d0 100][d1 100][x1 100 act][c0 50][x2 100 act][c1 50]
      [x3 100 act][x4 300 act]
    """
    store = mkstore(1000)
    store.load_model("x0", recs("x0", [100]))
    store.load_model("d0", recs("d0", [100]))
    store.release("d0")
    store.load_model("d1", recs("d1", [100]))
    store.release("d1")
    store.load_model("x1", recs("x1", [100]))
    store.load_model("c0", recs("c0", [50]))
    store.release("c0")
    store.load_model("x2", recs("x2", [100]))
    store.load_model("c1", recs("c1", [50]))
    store.release("c1")
    store.load_model("x3", recs("x3", [100]))
    store.load_model("x4", recs("x4", [300]))
    assert store.free_bytes() == 0
    return store


def test_urgent_reclaim_contiguous_where_plain_mce_fails():
    """Plain MCE reclaims the CHEAPEST (smallest) tensors first, which can
    free enough total bytes while leaving only sub-block holes; the sliding
    window must instead evict the one adjacent pair that opens a full hole."""
    plain = _sub_block_hole_store()
    freed = plain.urgent_reclaim(200)
    assert freed >= 200
    # cheapest-first took c0+c1 (+ one 100B): scattered holes, none >= 200
    assert plain.pool.largest_free() < 200

    windowed = _sub_block_hole_store()
    assert windowed.urgent_reclaim_contiguous(200)
    assert windowed.pool.largest_free() >= 200
    # minimal-cost window is exactly [d0][d1]; the cheap 50B tensors survive
    assert windowed.resident_bytes("c0") == 50
    assert windowed.resident_bytes("c1") == 50
    assert windowed.resident_bytes("d0") == 0
    assert windowed.resident_bytes("d1") == 0


def test_urgent_reclaim_contiguous_no_candidates_returns_false():
    store = mkstore(400)
    store.load_model("busy", recs("busy", [400]))  # active: not evictable
    assert not store.urgent_reclaim_contiguous(100)
    assert store.resident_bytes("busy") == 400  # nothing touched


def test_urgent_reclaim_contiguous_unsatisfiable_returns_false():
    store = _sub_block_hole_store()
    # no window of consecutive free/inactive regions reaches 500B
    assert not store.urgent_reclaim_contiguous(500)
    # a failed pass must not have evicted anything
    assert store.free_bytes() == 0
