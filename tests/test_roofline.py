"""HLO walker validation: the while-multiplied dot-FLOP count must match the
same computation with the loop unrolled (where XLA's own cost_analysis is
correct), and collective accounting must scale with trip count.

Runs in a subprocess so the forced device count stays out of this process.
"""
import json
import subprocess
import sys
import textwrap

SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.sharding import make_mesh_compat
    from repro.roofline.analysis import HloModule

    mesh = make_mesh_compat((2, 4), ("data", "model"), devices=jax.devices())
    L, D, F = 6, 64, 256

    def body(h, w):
        w1, w2 = w
        return jnp.tanh(h @ w1) @ w2, None

    def scanned(h, stack):
        return jax.lax.scan(body, h, stack)[0].astype(jnp.float32).mean()

    def unrolled(h, stack):
        return jax.lax.scan(body, h, stack, unroll=L)[0].astype(jnp.float32).mean()

    h = jax.ShapeDtypeStruct((16, D), jnp.bfloat16)
    stack = (jax.ShapeDtypeStruct((L, D, F), jnp.bfloat16),
             jax.ShapeDtypeStruct((L, F, D), jnp.bfloat16))
    sh = (NamedSharding(mesh, P("data", None)),
          (NamedSharding(mesh, P(None, None, "model")),
           NamedSharding(mesh, P(None, "model", None))))

    out = {}
    for name, fn in [("scanned", scanned), ("unrolled", unrolled)]:
        comp = jax.jit(fn, in_shardings=sh,
                       out_shardings=NamedSharding(mesh, P())).lower(h, stack).compile()
        mod = HloModule(comp.as_text(), trip_hints=[L])
        c = mod.entry_cost()
        ca = comp.cost_analysis()
        if isinstance(ca, list):  # jax <= 0.4.x: one dict per device
            ca = ca[0]
        out[name] = {"flops": c.flops, "coll": c.collective_bytes,
                     "xla_flops": ca.get("flops")}
    print("RESULT" + json.dumps(out))
""")


def test_walker_matches_unrolled_ground_truth():
    out = subprocess.run([sys.executable, "-c", SNIPPET], capture_output=True,
                         text=True, timeout=600,
                         env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert "RESULT" in out.stdout, out.stderr[-2000:]
    data = json.loads(out.stdout.split("RESULT")[1])
    scanned, unrolled = data["scanned"], data["unrolled"]
    # walker on the rolled loop ~= walker on the unrolled program
    assert scanned["flops"] == __import__("pytest").approx(
        unrolled["flops"], rel=0.05)
    # analytic matmul ground truth: L layers x 2 dots, per chip
    L, D, F, B_loc, F_loc = 6, 64, 256, 16 // 2, 256 // 4
    analytic = L * 2 * (2 * B_loc * D * F_loc)
    assert scanned["flops"] == __import__("pytest").approx(analytic, rel=0.01)
    # XLA (where correct, i.e. unrolled) counts dots PLUS elementwise, so it
    # upper-bounds the walker's dot-only number
    assert unrolled["xla_flops"] >= scanned["flops"]
    assert unrolled["xla_flops"] <= scanned["flops"] * 2.5
    # XLA undercounts the rolled program (body counted once) — the bug the
    # walker exists to fix
    assert scanned["xla_flops"] < scanned["flops"] / 2
    # collectives also scale with the trip count
    assert scanned["coll"] == __import__("pytest").approx(unrolled["coll"], rel=0.05)


def test_shape_parsing_helpers():
    from repro.roofline.analysis import _all_shapes, _nbytes, _parse_shape

    assert _parse_shape("bf16[16,4096]{1,0} fusion(...)") == ("bf16", [16, 4096])
    assert _nbytes(("f32", [8, 4])) == 128
    shapes = _all_shapes("(s32[], bf16[32,64]{1,0}, f32[4,256,64])")
    assert ("bf16", [32, 64]) in shapes and ("f32", [4, 256, 64]) in shapes


def test_model_flops_moe_counts_active_only():
    from repro.configs import SHAPES, all_configs
    from repro.roofline.analysis import active_params

    cfg = all_configs()["qwen3-moe-30b-a3b"]
    total = cfg.param_count()
    active = active_params(cfg)
    assert active < total / 5  # 8-of-128 experts
    dense = all_configs()["yi-9b"]
    assert active_params(dense) == dense.param_count()
