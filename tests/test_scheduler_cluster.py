"""Affinity scheduler (Algorithm 2) + cluster simulation end-to-end."""
import statistics as st

import pytest

from repro.core import (POLICIES, ClusterSim, PhaseCosts, ReuseStore,
                        affinity_schedule, estimate_load_time, generate_trace,
                        paper_l40, random_schedule, summarize)
from repro.core.trace import PAPER_MODELS, access_intervals
from repro.models.tensors import TensorRecord


def recs(model, sizes):
    return [TensorRecord(name=f"{model}/t{i}", shape=(s,), dtype="int8",
                         fingerprint=f"{model}/t{i}", nbytes=s)
            for i, s in enumerate(sizes)]


class FakeDevice:
    def __init__(self, device_id, resident, capacity=10**9):
        self.device_id = device_id
        self._resident = resident  # set of fingerprints
        self.capacity = capacity

    def can_run(self, model_bytes, model_id=None):
        return model_bytes <= self.capacity

    def reusable_bytes(self, records):
        return sum(r.nbytes for r in records if r.fingerprint in self._resident)


def test_affinity_picks_max_reuse_device():
    r = recs("m", [100, 200, 300])
    devs = [FakeDevice("g0", set()),
            FakeDevice("g1", {"m/t2"}),           # 300 reusable
            FakeDevice("g2", {"m/t0", "m/t1"})]   # 300 reusable (tie) -> first best kept
    hw = paper_l40()
    scheds, queued = affinity_schedule([("m", r, 600)], devs, hw)
    assert not queued
    assert scheds[0].device_id in ("g1", "g2")
    assert scheds[0].reuse_bytes == 300
    assert scheds[0].expected_load_seconds == pytest.approx(
        estimate_load_time(600, 300, hw))


def test_affinity_queues_when_no_feasible_device():
    devs = [FakeDevice("g0", set(), capacity=100)]
    scheds, queued = affinity_schedule([("m", recs("m", [500]), 500)], devs,
                                       paper_l40())
    assert queued == ["m"] and not scheds


def test_affinity_one_instance_per_device():
    r1, r2 = recs("a", [100]), recs("b", [100])
    devs = [FakeDevice("g0", set())]
    scheds, queued = affinity_schedule([("a", r1, 100), ("b", r2, 100)], devs,
                                       paper_l40())
    assert len(scheds) == 1 and queued == ["b"]


class FakeTieredDevice(FakeDevice):
    def __init__(self, device_id, resident, host_resident, capacity=10**9):
        super().__init__(device_id, resident, capacity)
        self._host = host_resident  # fingerprints the HOST tier caches

    def host_resident_bytes(self, records):
        return sum(r.nbytes for r in records
                   if r.fingerprint not in self._resident
                   and r.fingerprint in self._host)


def test_affinity_tier_aware_prefers_host_cached_misses():
    """Equal device-pool reuse: the node whose HOST tier caches the missing
    tensors must beat the one that would promote them from the persistent
    store at min(h2d_bw, store_bw) (DESIGN.md §11)."""
    from repro.core import estimate_load_time_tiered

    r = recs("m", [100, 200, 300])
    devs = [FakeTieredDevice("g0", {"m/t2"}, set()),        # misses from store
            FakeTieredDevice("g1", {"m/t2"}, {"m/t0", "m/t1"})]  # host-cached
    hw = paper_l40()
    scheds, queued = affinity_schedule([("m", r, 600)], devs, hw)
    assert not queued and scheds[0].device_id == "g1"
    assert scheds[0].expected_load_seconds == pytest.approx(
        estimate_load_time_tiered(600, 300, 300, hw))


def test_worker_host_resident_bytes_counts_only_device_misses():
    """A node whose host tier spilled exactly the device-MISSING tensors
    while retaining the device-resident ones must score zero host bytes —
    counting the residents' host copies would hide the store-tier promote
    the load will actually pay."""
    import dataclasses

    from repro.core import SimWorker

    pol = dataclasses.replace(POLICIES["tangram-tier"], host_cache_bytes=10**9)
    w = SimWorker("g0", 10**9, PhaseCosts(paper_l40()), pol)
    r = recs("m", [100, 200, 300])
    w.store.load_model("m", r)  # device + host tiers now hold all three
    w.store._evict("m/t0")  # drop t0 from the DEVICE pool only
    assert w.host_resident_bytes(r) == 100  # t0: the only miss, host-cached
    w.host_cache._evict("m/t0")  # host tier spills exactly the missing one
    assert w.host_resident_bytes(r) == 0  # t1/t2 host copies must not count


def test_trace_locality_levels():
    t_l1 = generate_trace(n_requests=400, locality="L1", seed=3)
    t_l4 = generate_trace(n_requests=400, locality="L4", seed=3)
    consec = lambda t: sum(a.model_id == b.model_id for a, b in zip(t, t[1:]))
    assert consec(t_l1) == 0
    assert consec(t_l4) > 50
    iv = access_intervals(t_l4)
    assert sum(v.count(0) for v in iv.values()) == consec(t_l4)


def test_cluster_policy_ladder():
    """Each added optimization must improve cold-start TTFT on a local trace."""
    trace = generate_trace(n_requests=250, locality="L3",
                           mean_interarrival=12.0, seed=7)
    cold_ttft = {}
    for pol in ["sllm", "sllm-c", "sllm-cm", "tangram"]:
        sim = ClusterSim(PAPER_MODELS, POLICIES[pol], n_workers=2, seed=5)
        res = sim.run(trace)
        cold = [r for r in res if not r.warm]
        cold_ttft[pol] = st.fmean(r.ttft for r in cold)
    assert cold_ttft["sllm-c"] < cold_ttft["sllm"]
    assert cold_ttft["sllm-cm"] < cold_ttft["sllm-c"]
    assert cold_ttft["tangram"] < cold_ttft["sllm-cm"]


def test_tangram_reduces_load_bytes():
    trace = generate_trace(n_requests=250, locality="L3",
                           mean_interarrival=12.0, seed=9)
    res_b = ClusterSim(PAPER_MODELS, POLICIES["sllm-cm"], n_workers=2, seed=5).run(trace)
    res_t = ClusterSim(PAPER_MODELS, POLICIES["tangram"], n_workers=2, seed=5).run(trace)
    bytes_b = sum(r.bytes_transferred for r in res_b)
    bytes_t = sum(r.bytes_transferred for r in res_t)
    assert bytes_t < bytes_b * 0.9


def test_affinity_beats_random_with_many_workers():
    trace = generate_trace(n_requests=300, locality="L2",
                           mean_interarrival=3.0, seed=11)
    import dataclasses
    no_aff = dataclasses.replace(POLICIES["tangram"], name="noaff", affinity=False)
    res_a = ClusterSim(PAPER_MODELS, POLICIES["tangram"], n_workers=6, seed=5).run(trace)
    res_r = ClusterSim(PAPER_MODELS, no_aff, n_workers=6, seed=5).run(trace)
    load_a = st.fmean(r.load_phase for r in res_a if not r.warm)
    load_r = st.fmean(r.load_phase for r in res_r if not r.warm)
    assert load_a <= load_r * 1.02  # affinity should not be worse


def test_decode_results_have_overhead_accounting():
    trace = generate_trace(n_requests=60, locality="L3", seed=13,
                           mean_interarrival=25.0, batch_size=4)
    res = ClusterSim(PAPER_MODELS, POLICIES["tangram"], n_workers=1, seed=5).run(trace)
    assert all(r.kv_overhead_s >= 0 for r in res)
    assert all(r.decode_s > 0 for r in res)
    # ODKV overhead stays tiny relative to decode (paper: < 3.2%)
    tot_overhead = sum(r.kv_overhead_s for r in res)
    tot_decode = sum(r.decode_s for r in res)
    assert tot_overhead / tot_decode < 0.05


def test_fault_injection_and_recovery():
    """A worker dies mid-trace: its state is wiped, requests keep completing
    on survivors, and the node rejoins cold after recovery."""
    trace = generate_trace(n_requests=120, locality="L3",
                           mean_interarrival=10.0, seed=33)
    sim = ClusterSim(PAPER_MODELS, POLICIES["tangram"], n_workers=3, seed=5)
    fail_t = trace[40].time + 0.1
    sim.inject_failure(fail_t, "gpu0", recover_after=200.0)
    res = sim.run(trace)
    # the fleet keeps serving: most requests complete despite the failure
    assert len(res) >= 110
    dead = next(w for w in sim.workers if w.device_id == "gpu0")
    assert not dead.failed  # recovered by end of trace
    assert dead.store.resident_bytes() >= 0  # fresh (cold) pool object


def test_failure_without_recovery_shrinks_fleet():
    trace = generate_trace(n_requests=80, locality="L3",
                           mean_interarrival=10.0, seed=34)
    sim = ClusterSim(PAPER_MODELS, POLICIES["tangram"], n_workers=2, seed=5)
    sim.inject_failure(trace[10].time + 0.1, "gpu1")
    res = sim.run(trace)
    assert len(res) >= 60  # survivor handles the load
    # nothing was ever scheduled onto the dead node afterwards
    late = [r for r in res if r.start > trace[10].time + 1]
    assert all(not sim.workers[1].busy_model for _ in late)


# --------------------------------------------- live KV migration (§16)
class OfferDevice(FakeDevice):
    """FakeDevice + the optional queue/migration DeviceView methods."""

    def __init__(self, device_id, resident, *, delay=0.0, offer=None):
        super().__init__(device_id, resident, capacity=int(20e9))
        self.delay = delay
        self.offer = offer

    def expected_queue_delay(self, now):
        return self.delay

    def migration_offer(self, now):
        return self.offer


class TestMigrationOffer:
    def test_offer_replaces_queue_delay_and_flags_entry(self):
        r = recs("m", [6_000_000_000])  # 6 GB: a cold load costs ~1.2 s
        busy = OfferDevice("g0", {"m/t0"}, delay=120.0, offer=0.05)
        idle = OfferDevice("g1", set(), delay=0.0)
        scheds, _ = affinity_schedule([("m", r, 6_000_000_000)],
                                     [busy, idle], paper_l40(),
                                     policy="eq3+queue")
        # resident bytes + a cheap handoff beat the idle cold device
        assert scheds[0].device_id == "g0" and scheds[0].migrate

    def test_worse_offer_is_ignored(self):
        r = recs("m", [600])
        busy = OfferDevice("g0", {"m/t0"}, delay=0.01, offer=5.0)
        scheds, _ = affinity_schedule([("m", r, 600)], [busy], paper_l40(),
                                     policy="eq3+queue")
        assert scheds[0].device_id == "g0" and not scheds[0].migrate

    def test_pure_eq3_never_consults_offers(self):
        r = recs("m", [600])
        busy = OfferDevice("g0", {"m/t0"}, delay=120.0, offer=0.05)
        scheds, _ = affinity_schedule([("m", r, 600)], [busy], paper_l40(),
                                     policy="eq3")
        assert not scheds[0].migrate


def _migration_trace():
    from repro.core.trace import Request

    models = PAPER_MODELS[4:8]
    L, S, M = (models[1].model_id, models[2].model_id, models[3].model_id)

    def rq(t, mid, out=16):
        return Request(time=t, model_id=mid, dataset="gsm8k",
                       prompt_tokens=64, output_tokens=out, batch_size=1)
    return models, [rq(0.0, L, out=4096), rq(1.0, S, out=4096),
                    rq(10.0, M), rq(20.0, M), rq(30.0, M)]


class TestSimMigration:
    def _run(self, policy):
        models, trace = _migration_trace()
        sim = ClusterSim(models, POLICIES[policy], n_workers=2,
                         pool_bytes=int(20e9), seed=7)
        res = sim.run(trace)
        return sim, res

    def test_sim_migrates_and_replays_exact(self):
        a, ra = self._run("tangram-migrate")
        b, rb = self._run("tangram-migrate")
        assert a.migrations > 0
        assert a.migrate_log == b.migrate_log
        assert [r.__dict__ for r in ra] == [r.__dict__ for r in rb]
        # the handoff's source stall precedes its target completion
        for t, model, src, dst, stall, done in a.migrate_log:
            assert src != dst and stall > 0.0 and done > t + stall
        # every request still completes exactly once
        assert len(ra) == len(_migration_trace()[1])

    def test_migrate_off_policy_never_migrates(self):
        sim, res = self._run("tangram-serverless")
        assert sim.migrations == 0 and sim.migrate_log == []
        assert len(res) == len(_migration_trace()[1])

    def test_source_slot_frees_after_stall(self):
        """After the handoff, the source worker's victim completes at the
        snapshot stall (its replacement done event), not the original
        residual — the event the golden log's stall column prices."""
        a, _ = self._run("tangram-migrate")
        t, model, src, dst, stall, done = a.migrate_log[0]
        srcw = next(w for w in a.workers if w.device_id == src)
        dstw = next(w for w in a.workers if w.device_id == dst)
        # both sides drained by end of trace; the moved model's weights
        # landed (activate) on the target's accounting
        assert not srcw.busy_instances() and not dstw.busy_instances()
