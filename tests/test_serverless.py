"""Serverless control plane (DESIGN.md §13): workload driver, lifecycle
manager, tenant-pressure resize paths, gateway metrics, and the end-to-end
cluster-sim wiring.  Deterministic and subprocess-free — part of the fast
CI subset (tests/fast_tests.txt)."""
import dataclasses

import numpy as np
import pytest

from repro.core import POLICIES, ClusterSim, PAPER_MODELS
from repro.core.hostcache import SimHostCache
from repro.models.tensors import HostTensorStore, TensorRecord
from repro.serverless import (MetricsSink, PressureEvent, burst_trace,
                              diurnal_trace, make_trace, percentile,
                              poisson_trace, pressure_walk, pressure_wave,
                              run_serverless_sim)
from repro.serverless.lifecycle import (AdaptiveHistogram, FixedTTL,
                                        InstanceState, LifecycleManager,
                                        make_keep_alive)

MODELS = PAPER_MODELS[2:6]


def recs(model_id, sizes):
    return [TensorRecord(name=f"{model_id}/t{i}", shape=(s,), dtype="uint8",
                         fingerprint=f"{model_id}/t{i}", nbytes=s)
            for i, s in enumerate(sizes)]


# ---------------------------------------------------------------- workload
@pytest.mark.parametrize("kind", ["poisson", "diurnal", "burst"])
def test_traces_deterministic_sorted_and_sized(kind):
    a = make_trace(kind, n_requests=80, models=MODELS, seed=5)
    b = make_trace(kind, n_requests=80, models=MODELS, seed=5)
    c = make_trace(kind, n_requests=80, models=MODELS, seed=6)
    assert a == b  # seeded: replay-exact
    assert a != c  # and the seed actually matters
    assert len(a) == 80
    assert all(x.time <= y.time for x, y in zip(a, a[1:]))
    ids = {m.model_id for m in MODELS}
    assert all(r.model_id in ids for r in a)


def test_unknown_arrival_kind_rejected():
    with pytest.raises(ValueError):
        make_trace("weibull", n_requests=4)


def test_diurnal_rate_actually_modulates():
    """Lewis thinning must produce more arrivals near the sinusoid's peak
    than its trough — count arrivals per half-period phase."""
    period = 200.0
    trace = diurnal_trace(n_requests=600, models=MODELS, seed=3,
                          mean_interarrival=2.0, period_s=period,
                          amplitude=0.8)
    peak = sum(1 for r in trace if (r.time % period) < period / 2)
    trough = len(trace) - peak
    assert peak > 1.5 * trough


def test_burst_trace_has_volleys_at_hot_models():
    trace = burst_trace(n_requests=200, models=MODELS, seed=11,
                        mean_interarrival=10.0, burst_every_s=120.0,
                        burst_size=6, burst_models=2, burst_window_s=2.0)
    # find a window of 6 consecutive requests inside 2 s: a volley
    volleys = [trace[i : i + 6] for i in range(len(trace) - 5)
               if trace[i + 5].time - trace[i].time <= 2.0]
    assert volleys, "no burst volley landed"
    # volleys target the configured number of hot models (a background
    # arrival may straddle a window, so SOME pure volley must exist)
    assert any(len({r.model_id for r in v}) <= 2 for v in volleys)


def test_poisson_mean_interarrival_in_range():
    trace = poisson_trace(n_requests=500, models=MODELS, seed=1,
                          mean_interarrival=10.0)
    gaps = [y.time - x.time for x, y in zip(trace, trace[1:])]
    assert 8.0 < sum(gaps) / len(gaps) < 12.0


def test_pressure_wave_alternates_and_walk_stays_bounded():
    base = 1000
    wave = pressure_wave(horizon_s=1000.0, base_bytes=base, low_frac=0.5,
                         period_s=200.0, duty=0.5)
    assert wave and wave[0].capacity_bytes == 500
    caps = [p.capacity_bytes for p in wave]
    assert set(caps) == {500, 1000}
    assert caps == [500, 1000] * (len(caps) // 2) + [500] * (len(caps) % 2)
    assert all(x.time < y.time for x, y in zip(wave, wave[1:]))
    walk = pressure_walk(horizon_s=1000.0, base_bytes=base, step_s=50.0,
                         low_frac=0.4, seed=2)
    assert walk == pressure_walk(horizon_s=1000.0, base_bytes=base,
                                 step_s=50.0, low_frac=0.4, seed=2)
    assert all(400 <= p.capacity_bytes <= 1000 for p in walk)


# --------------------------------------------------------------- lifecycle
def test_make_keep_alive_specs():
    assert make_keep_alive("zero").ttl("m") == 0.0
    assert make_keep_alive("fixed:17.5").ttl("m") == 17.5
    assert isinstance(make_keep_alive("adaptive"), AdaptiveHistogram)
    assert make_keep_alive("adaptive:0.5").percentile == 0.5
    with pytest.raises(ValueError):
        make_keep_alive("sometimes")


def test_adaptive_learns_typical_gap():
    pol = AdaptiveHistogram(bucket_s=5.0, percentile=0.95, margin=1.0,
                            min_ttl=2.0, max_ttl=300.0, default_ttl=60.0,
                            min_samples=4)
    assert pol.ttl("m") == 60.0  # unseen model: default
    for _ in range(20):
        pol.observe("m", 12.0)  # gaps land in the [10, 15) bucket
    assert pol.ttl("m") == 15.0  # covers the bucket's upper edge
    # a model whose gaps exceed the window scales down fast, not up
    for _ in range(20):
        pol.observe("sparse", 1e6)
    assert pol.ttl("sparse") == 2.0


def test_adaptive_percentile_tracks_tail_not_mode():
    pol = AdaptiveHistogram(bucket_s=5.0, percentile=0.95, margin=1.0,
                            min_samples=4, default_ttl=60.0)
    for _ in range(90):
        pol.observe("m", 3.0)
    for _ in range(10):
        pol.observe("m", 43.0)  # 10% of gaps are ~45 s
    assert pol.ttl("m") == 45.0  # p95 sits inside the tail bucket


def test_manager_states_counters_and_log_are_deterministic():
    def run():
        mgr = LifecycleManager(FixedTTL(10.0))
        mgr.observe_arrival("m", 1.0)
        assert mgr.state_of("m") is InstanceState.COLD
        mgr.on_start("m", 1.0, warm=False)
        assert mgr.state_of("m") is InstanceState.LIVE
        assert mgr.on_idle("m", 5.0) == 10.0
        assert mgr.state_of("m") is InstanceState.WARM
        mgr.observe_arrival("m", 9.0)
        mgr.on_start("m", 9.0, warm=True)
        mgr.on_idle("m", 12.0)
        mgr.on_expire("m", 22.0)
        assert mgr.state_of("m") is InstanceState.COLD
        return mgr

    a, b = run(), run()
    assert a.log == b.log
    assert a.counters.cold_starts == 1 and a.counters.warm_starts == 1
    assert a.counters.expirations == 1 and a.counters.arrivals == 2
    assert a.summary()["cold_start_rate"] == 0.5


def test_scale_to_zero_manager_goes_cold_at_idle():
    mgr = LifecycleManager(make_keep_alive("zero"))
    mgr.on_start("m", 0.0, warm=False)
    assert mgr.on_idle("m", 1.0) == 0.0
    assert mgr.state_of("m") is InstanceState.COLD


# ---------------------------------------------------- capacity resize paths
def test_sim_hostcache_shrink_spills_lru_first():
    hc = SimHostCache(1000)
    r = recs("m", [400, 300, 200])
    hc.plan_fetch(r, now=0.0)
    hc.plan_fetch(r[:1], now=1.0)  # touch t0: it becomes MRU
    spilled = hc.set_capacity_bytes(500)
    # LRU order spills t1 (300) then t2 (200); MRU t0 survives
    assert spilled == 500
    assert r[0].fingerprint in hc
    assert r[1].fingerprint not in hc and r[2].fingerprint not in hc
    assert hc.nbytes() == 400
    assert hc.pressure_evictions == 2
    assert hc.set_capacity_bytes(2000) == 0  # growth never spills
    assert hc.nbytes() == 400
    # the strict cost contract: re-reading the shrink-spilled tensors pays
    # the store tier again (a set_capacity_bytes that only bumped counters
    # without evicting would return (500, 0) here and fail)
    assert hc.plan_fetch(r, now=2.0) == (400, 500)


def test_host_store_shrink_respects_pins():
    """Eviction-on-shrink must skip pinned (loading / device-active)
    tensors even when that leaves the store over its new cap — a pressure
    squeeze can never deadlock a pinned load (the fig16 acceptance)."""
    hs = HostTensorStore(1000)
    for fp, n in (("a", 400), ("b", 300), ("c", 200)):
        hs.put(fp, np.zeros(n, np.uint8))
    hs.pin("a")
    hs.pin("b")
    # returns BYTES spilled (same unit as SimHostCache.set_capacity_bytes)
    assert hs.set_capacity_bytes(100) == 200
    # only the unpinned tensor spilled; pinned bytes sit above the cap
    assert "a" in hs and "b" in hs and "c" not in hs
    assert hs.nbytes() == 700 > 100
    assert hs.pinned_nbytes() == 700
    # releasing a pin makes its bytes evictable immediately
    hs.unpin("b")
    assert "b" not in hs and hs.nbytes() == 400
    # and the spilled tensors stayed resolvable (promote path intact)
    assert hs.spill.nbytes() == 500
    hs.set_capacity_bytes(1000)
    assert hs.fetch("c").nbytes == 200


# ----------------------------------------------------------------- gateway
def test_metrics_sink_percentiles_and_cold_rate():
    sink = MetricsSink()
    from repro.serverless.gateway import TTFTRecord

    for i in range(100):
        sink.add(TTFTRecord(model_id="m", arrival=float(i), cold=i < 20,
                            load_s=float(i)))
    s = sink.summary()
    assert s["n"] == 100 and s["cold_start_rate"] == 0.2
    assert s["ttft_p50"] == 50.0 and s["ttft_p95"] == 95.0
    assert s["cold_ttft_p95"] == 19.0  # over the 20 cold records only
    assert percentile([], 0.5) == 0.0
    assert MetricsSink().summary() == {"n": 0, "fault_events": 0}


# -------------------------------------------------------------- end to end
def _sweep(ka: str, pressure=()):
    trace = make_trace("poisson", n_requests=100, models=MODELS, seed=7,
                       mean_interarrival=12.0, max_output_tokens=128)
    pol = dataclasses.replace(POLICIES["tangram-serverless"],
                              name=f"t-{ka}", lifecycle=ka)
    return run_serverless_sim(MODELS, trace, pol, n_workers=2, seed=7,
                              pressure=pressure)


def test_sim_lifecycle_counters_match_results():
    sim, sink = _sweep("adaptive")
    s = sink.summary()
    ls = sim.lifecycle.summary()
    assert s["n"] == 100
    assert ls["cold_starts"] + ls["warm_starts"] == s["n"]
    assert ls["cold_starts"] == s["cold_starts"]


def test_sim_scale_to_zero_leaves_no_idle_instances():
    sim, _ = _sweep("zero")
    for w in sim.workers:
        assert not w.idle_instances()  # every idle terminated immediately


def test_sim_adaptive_beats_scale_to_zero():
    _, zero = _sweep("zero")
    _, adpt = _sweep("adaptive")
    assert adpt.summary()["cold_start_rate"] < \
        zero.summary()["cold_start_rate"]
    assert adpt.summary()["ttft_p95"] <= zero.summary()["ttft_p95"]


def test_sim_pressure_squeeze_spills_but_never_deadlocks():
    trace = make_trace("poisson", n_requests=100, models=MODELS, seed=7,
                       mean_interarrival=12.0, max_output_tokens=128)
    press = pressure_wave(horizon_s=trace[-1].time,
                          base_bytes=sum(m.bytes for m in MODELS),
                          low_frac=0.5, period_s=120.0)
    sim, sink = _sweep("adaptive", pressure=press)
    s = sink.summary()
    assert s["n"] == 100  # every request completed under the squeeze
    assert sum(w.host_cache.pressure_evictions for w in sim.workers) > 0
    # >=, not >: a tidy squeeze's LRU spills the bytes LEAST likely to be
    # re-read, so store traffic often matches the calm run exactly — the
    # strict re-pay contract is pinned at the cache level in
    # test_sim_hostcache_shrink_spills_lru_first; this is the fleet-level
    # safety half (evictions happened, nothing deadlocked or got cheaper)
    _, calm = _sweep("adaptive")
    assert s["bytes_from_store"] >= calm.summary()["bytes_from_store"]


def test_sim_legacy_policies_unaffected_by_lifecycle_field():
    """tangram-prefetch (lifecycle=None) must be byte-for-byte identical to
    its pre-control-plane behaviour — the subsystem is opt-in."""
    trace = make_trace("poisson", n_requests=60, models=MODELS, seed=3,
                       mean_interarrival=12.0, max_output_tokens=128)
    runs = []
    for _ in range(2):
        sim = ClusterSim(MODELS, POLICIES["tangram-prefetch"], n_workers=2,
                         seed=3)
        runs.append(sim.run(trace))
        assert sim.lifecycle is None
    assert runs[0] == runs[1]


def test_pressure_event_reaches_every_worker():
    trace = make_trace("poisson", n_requests=30, models=MODELS, seed=2,
                       mean_interarrival=12.0, max_output_tokens=64)
    sim = ClusterSim(MODELS, POLICIES["tangram-serverless"], n_workers=2,
                     seed=2)
    cap = int(1e9)
    sim.run(trace, pressure=[PressureEvent(1.0, cap)])
    for w in sim.workers:
        assert w.host_cache.capacity_bytes == cap
