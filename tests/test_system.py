"""End-to-end behaviour of the full system: the paper's headline claims hold
on this implementation (cold-start speedup, reuse accounting, overheads)."""
import statistics as st

import pytest

from repro.core import POLICIES, ClusterSim, PAPER_MODELS, generate_trace


@pytest.fixture(scope="module")
def results():
    trace = generate_trace(n_requests=300, locality="L3",
                           mean_interarrival=12.0, seed=42)
    out = {}
    for pol in ["sllm", "sllm-cm", "tangram"]:
        sim = ClusterSim(PAPER_MODELS, POLICIES[pol], n_workers=2, seed=7)
        out[pol] = sim.run(trace)
    return out


def test_tangram_beats_sllm_cm_ttft(results):
    cold = {p: [r for r in rs if not r.warm] for p, rs in results.items()}
    ttft = {p: st.fmean(r.ttft - r.queue_s for r in rs)
            for p, rs in cold.items()}
    assert ttft["tangram"] < ttft["sllm-cm"] < ttft["sllm"]
    reduction = 1 - ttft["tangram"] / ttft["sllm-cm"]
    assert reduction > 0.10, f"only {reduction:.0%} TTFT reduction"


def test_load_phase_speedup_band(results):
    cold = {p: [r for r in rs if not r.warm] for p, rs in results.items()}
    load = {p: st.fmean(r.load_phase for r in rs) for p, rs in cold.items()}
    speedup = load["sllm-cm"] / load["tangram"]
    assert speedup > 1.3, f"load speedup only {speedup:.2f}x"


def test_reuse_only_happens_for_tangram(results):
    assert all(r.reuse_fraction == 0 for r in results["sllm"])
    assert any(r.reuse_fraction > 0.5 for r in results["tangram"])


def test_decode_overhead_negligible(results):
    tot_overhead = sum(r.kv_overhead_s for r in results["tangram"])
    tot_decode = sum(r.decode_s for r in results["tangram"])
    assert tot_overhead / tot_decode < 0.032  # the paper's own bound


def test_conservation_of_bytes(results):
    """Cold starts transfer exactly (1 - reuse_fraction) x model bytes."""
    sizes = {m.model_id: m.bytes for m in PAPER_MODELS}
    for r in results["tangram"]:
        if r.warm:
            assert r.bytes_transferred == 0
        elif r.reuse_fraction < 1:
            expected = sizes[r.model_id]
            got = r.bytes_transferred / (1 - r.reuse_fraction)
            assert abs(got - expected) / expected < 0.01
