"""Training substrate: optimizer behaviour, data pipeline, checkpointing
(async, elastic), loss actually decreases on the bigram task."""
import dataclasses
import os
import shutil

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.train.checkpoint import CheckpointManager, latest_step, restore, save
from repro.train.data import BigramStream, DataConfig
from repro.train.optimizer import AdamWConfig, adamw_update, global_norm, init_opt_state


def tiny_model():
    cfg = dataclasses.replace(
        get_config("llama3.2-1b"), num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        tie_embeddings=True)
    return cfg, build_model(cfg)


def test_loss_decreases_on_bigram_task():
    cfg, model = tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    data = BigramStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                   global_batch=8, branching=4))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=10)
    opt_state = init_opt_state(params)

    @jax.jit
    def step(params, opt_state, tokens):
        loss, g = jax.value_and_grad(
            lambda p: model.loss(p, {"tokens": tokens}, remat=False))(params)
        params, opt_state, _ = adamw_update(params, g, opt_state, opt_cfg)
        return params, opt_state, loss

    losses = []
    for i in range(60):
        params, opt_state, loss = step(params, opt_state, data.batch(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])


def test_grad_clip_bounds_update():
    cfg, model = tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(grad_clip=0.5)
    state = init_opt_state(params)
    big_grads = jax.tree.map(lambda p: jnp.full(p.shape, 100.0, jnp.float32), params)
    new_params, new_state, metrics = adamw_update(params, big_grads, state, opt_cfg)
    assert metrics["grad_norm"] > 0.5  # raw norm reported
    assert int(new_state["step"]) == 1


def test_data_determinism_and_sharding():
    data = BigramStream(DataConfig(vocab_size=128, seq_len=32, global_batch=8))
    b1 = data.batch(3)
    b2 = data.batch(3)
    assert jnp.array_equal(b1, b2)
    s0 = data.batch(3, shard=0, num_shards=2)
    s1 = data.batch(3, shard=1, num_shards=2)
    assert s0.shape == (4, 32)
    assert not jnp.array_equal(s0, s1)
    # bigram structure: every transition comes from the table
    tbl = data.table
    ok = [int(b1[i, t + 1]) in tbl[int(b1[i, t])].tolist()
          for i in range(4) for t in range(10)]
    assert all(ok)


def test_checkpoint_roundtrip_and_gc(tmp_path):
    cfg, model = tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        mgr.save(s, {"params": params, "opt": opt})
    mgr.wait()
    assert latest_step(str(tmp_path)) == 30
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step-"))
    assert len(kept) == 2  # gc keeps newest 2

    like = {"params": jax.tree.map(jnp.zeros_like, params),
            "opt": jax.tree.map(jnp.zeros_like, opt)}
    restored = mgr.restore_latest(like)
    for a, b in zip(jax.tree.leaves(restored["params"]), jax.tree.leaves(params)):
        assert jnp.array_equal(a, b)


def test_checkpoint_detects_tree_mismatch(tmp_path):
    cfg, model = tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    save(str(tmp_path), 1, {"params": params})
    with pytest.raises(AssertionError):
        restore(str(tmp_path), {"params": params, "extra": jnp.zeros(3)})


def test_elastic_restore_with_new_sharding(tmp_path):
    """Restore re-device_puts every leaf onto provided shardings."""
    cfg, model = tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    save(str(tmp_path), 5, params)
    shardings = jax.tree.map(
        lambda p: jax.sharding.SingleDeviceSharding(jax.devices()[0]), params)
    restored = restore(str(tmp_path), params, shardings=shardings)
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding == jax.sharding.SingleDeviceSharding(jax.devices()[0])
